import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")   # silence SPMD warnings

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, WITHOUT allocating a single parameter.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--out results.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all

For each cell we report ``memory_analysis()`` (fits-per-device proof),
``cost_analysis()`` FLOPs/bytes, and the collective-byte sums parsed from
the HLO — the inputs to EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import dataclasses
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import LM_ARCHS, SHAPES, cell_is_runnable, get_config
from repro.core import roofline as rl
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.models.params import abstract, logical_axes
from repro.sharding import fix_divisibility, spec_tree, use_mesh
from repro.train import optim


def _opt_state_abstract(params_abs):
    f32 = jnp.float32
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, f32)
    return optim.AdamWState(jax.tree.map(zeros, params_abs),
                            jax.tree.map(zeros, params_abs),
                            jax.ShapeDtypeStruct((), jnp.int32))


def build_step(cfg, shape_name: str):
    """(step_fn, abstract inputs dict, logical-axes dict, donate, out_axes)
    for the cell. ``out_axes``: logical axes for the step OUTPUTS — pinning
    them makes GSPMD lower fsdp gradient reductions as reduce-scatter
    instead of all-reduce (§Perf cell B, iteration B3)."""
    _, _, kind = SHAPES[shape_name]
    pdefs = lm.param_defs(cfg)
    params_abs, params_ax = abstract(pdefs), logical_axes(pdefs)

    if kind == "train":
        lr_fn = optim.cosine_schedule(3e-4, 100, 10_000)

        def train_step(params, opt_state, batch, step):
            (loss, _), grads = jax.value_and_grad(
                lm.lm_loss, has_aux=True, argnums=1)(cfg, params, batch)
            grads, _ = optim.clip_by_global_norm(grads, 1.0)
            params, opt_state = optim.adamw_update(
                grads, opt_state, params, lr=lr_fn(step))
            return params, opt_state, loss

        opt_abs = _opt_state_abstract(params_abs)
        opt_ax = optim.AdamWState(params_ax, params_ax, ())
        batch_abs = mesh_mod.input_specs(cfg, shape_name)
        batch_ax = mesh_mod.input_axes(cfg, shape_name)
        args = dict(params=params_abs, opt_state=opt_abs, batch=batch_abs,
                    step=jax.ShapeDtypeStruct((), jnp.int32))
        axes = dict(params=params_ax, opt_state=opt_ax, batch=batch_ax,
                    step=())
        out_axes = (params_ax, opt_ax, ())
        out_abs = (params_abs, opt_abs, jax.ShapeDtypeStruct((), jnp.float32))
        return train_step, args, axes, (0, 1), (out_axes, out_abs)

    if kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = lm.forward(cfg, params, batch["tokens"],
                                   image_embeds=batch.get("image_embeds"),
                                   encoder_frames=batch.get("encoder_frames"))
            return logits

        batch_abs = mesh_mod.input_specs(cfg, shape_name)
        batch_ax = mesh_mod.input_axes(cfg, shape_name)
        return (prefill_step, dict(params=params_abs, batch=batch_abs),
                dict(params=params_ax, batch=batch_ax), (), None)

    # decode
    def serve_step(params, cache, batch):
        logits, cache = lm.decode_step(cfg, params, cache,
                                       batch["tokens"], batch["position"])
        return logits, cache

    cache_abs, cache_ax = mesh_mod.decode_state_specs(cfg, shape_name)
    batch_abs = mesh_mod.input_specs(cfg, shape_name)
    batch_ax = mesh_mod.input_axes(cfg, shape_name)
    return (serve_step, dict(params=params_abs, cache=cache_abs,
                             batch=batch_abs),
            dict(params=params_ax, cache=cache_ax, batch=batch_ax), (1,),
            None)


def _scaled_cfg(cfg, repeats: int, enc_layers=None):
    """Same block pattern, ``repeats`` copies of the period block, UNROLLED
    so every layer's ops (and collectives) appear in the HLO for costing."""
    import repro.models.lm as _lm
    period = _lm.block_period(cfg)
    kw = dict(num_layers=period * repeats, scan_layers=False)
    if cfg.encoder_layers:
        kw["encoder_layers"] = (enc_layers if enc_layers is not None
                                else cfg.encoder_layers)
    return dataclasses.replace(cfg, **kw)


def _compile_cell(cfg, shape_name, mesh, rules):
    step_fn, args, axes, donate, outs = build_step(cfg, shape_name)
    shardings = fix_divisibility(spec_tree(axes, mesh, rules), args)
    kw = {}
    if outs is not None:
        out_axes, out_abs = outs
        kw["out_shardings"] = fix_divisibility(
            spec_tree(out_axes, mesh, rules), out_abs)
    with use_mesh(mesh, rules):
        jitted = jax.jit(step_fn,
                         in_shardings=tuple(shardings[k] for k in args),
                         donate_argnums=donate, **kw)
        lowered = jitted.lower(*[args[k] for k in args])
        compiled = lowered.compile()
    return compiled


def _costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = rl.collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            float(sum(coll.values())), coll)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True):
    """Compile the FULL config (fits-proof + deliverable) and extrapolate
    exact per-step costs from R=1 / R=2 period-block compiles.

    XLA's cost_analysis counts while-loop bodies ONCE (verified empirically),
    so a scan-over-layers program under-reports FLOPs by the trip count.
    Layer stacks are homogeneous in the period block, making per-step cost
    exactly linear in the repeat count R: cost(R) = a + R*b. Two cheap
    compiles recover (a, b); the full R is then priced exactly.
    """
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        return dict(arch=arch, shape=shape_name, skipped=why)
    cfg = get_config(arch)
    import repro.models.lm as _lm
    R_full = _lm.num_repeats(cfg)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(map(str, mesh.devices.shape))
    rules = mesh_mod.shape_rules(cfg, shape_name)

    t0 = time.monotonic()
    compiled = _compile_cell(cfg, shape_name, mesh, rules)   # full config
    t_compile = time.monotonic() - t0

    # cost extrapolation over the scan trip count
    c1 = _costs(_compile_cell(_scaled_cfg(cfg, 1, enc_layers=1),
                              shape_name, mesh, rules))
    c2 = _costs(_compile_cell(_scaled_cfg(cfg, 2, enc_layers=1),
                              shape_name, mesh, rules))
    slope = [c2[i] - c1[i] for i in range(3)]
    cost = [c1[i] + slope[i] * (R_full - 1) for i in range(3)]
    if cfg.encoder_layers > 1:                # whisper: encoder scan term
        c1e = _costs(_compile_cell(_scaled_cfg(cfg, 1, enc_layers=2),
                                   shape_name, mesh, rules))
        for i in range(3):
            cost[i] += (c1e[i] - c1[i]) * (cfg.encoder_layers - 1)
    flops, byts, coll = cost

    mem = compiled.memory_analysis()
    r = rl.Roofline(arch, shape_name, mesh_name, mesh.devices.size,
                    flops * mesh.devices.size, byts * mesh.devices.size,
                    coll * mesh.devices.size, c2[3],
                    mesh_mod.model_flops(cfg, shape_name))
    row = r.row()
    row.update(
        output_bytes_per_device=getattr(mem, "output_size_in_bytes", 0)
        / mesh.devices.size,
        temp_bytes_per_device=getattr(mem, "temp_size_in_bytes", 0)
        / mesh.devices.size,
        compile_s=round(t_compile, 1), multi_pod=multi_pod)
    if verbose:
        print(f"[{arch} x {shape_name} @ {mesh_name}] "
              f"compile={t_compile:.1f}s "
              f"flops/dev={flops/1e9:.1f}G bytes/dev={byts/1e9:.2f}GB "
              f"coll/dev={coll/1e9:.3f}GB bottleneck={r.bottleneck} "
              f"useful={r.useful_flop_frac:.2f} "
              f"roofline_frac={r.roofline_frac:.3f}", flush=True)
    return row


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default=None)
    a = p.parse_args()

    archs = LM_ARCHS if (a.all or not a.arch) else [a.arch]
    shapes = list(SHAPES) if (a.all or not a.shape) else [a.shape]
    meshes = [False, True] if a.both_meshes else [a.multi_pod]
    rows = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rows.append(run_cell(arch, shape, mp))
                except Exception as e:
                    rows.append(dict(arch=arch, shape=shape,
                                     multi_pod=mp, error=repr(e)[:500]))
                    print(f"[{arch} x {shape}] FAILED: {e!r}", file=sys.stderr)
                if a.out:
                    with open(a.out, "w") as f:
                        for r in rows:
                            f.write(json.dumps(r) + "\n")
    n_err = sum(1 for r in rows if "error" in r)
    print(f"\n{len(rows)} cells, {n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
