"""Serving driver: batched continuous-batching engine over a smoke model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--requests 8] [--batch 4] [--max-seq 128] [--int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import lm
from repro.models.params import materialize
from repro.serve.engine import Request, ServeEngine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--int8", action="store_true")
    a = p.parse_args()

    cfg = get_smoke(a.arch)
    params = materialize(lm.param_defs(cfg), jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=a.batch, max_seq=a.max_seq,
                      quantize=a.int8)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for uid in range(a.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, cfg.vocab_size,
                                               plen).astype(np.int32),
                           max_new_tokens=a.max_new))
    done = eng.run()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, int8={a.int8})")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
