"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        [--smoke] [--steps N] [--batch B] [--seq S] [--ckpt-dir DIR] \
        [--compress-grads] [--mesh auto|production|multipod]

On this CPU container use --smoke (reduced config, real optimization); the
full configs are exercised via the dry-run. The same driver runs on a real
TPU slice: the mesh is built from the live device set and in_shardings come
from the same logical-axis rules the dry-run proved out.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import synthetic
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.models.params import abstract, logical_axes, materialize
from repro.sharding import fix_divisibility, spec_tree, use_mesh
from repro.train import checkpoint as ckpt_mod
from repro.train import compress as compress_mod
from repro.train import optim


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--mesh", default="auto",
                   choices=["auto", "production", "multipod"])
    a = p.parse_args()

    cfg = get_smoke(a.arch) if a.smoke else get_config(a.arch)
    mesh = (mesh_mod.make_mesh_from_devices(
                model_parallel=min(4, len(jax.devices())))
            if a.mesh == "auto" else
            mesh_mod.make_production_mesh(multi_pod=a.mesh == "multipod"))
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"params={cfg.param_count():,}")

    pdefs = lm.param_defs(cfg)
    lr_fn = optim.cosine_schedule(a.lr, warmup=max(1, a.steps // 10),
                                  total=a.steps)

    def train_step(params, opt_state, err, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lm.lm_loss, has_aux=True, argnums=1)(cfg, params, batch)
        if a.compress_grads:
            q, s, err = compress_mod.compress(grads, err)
            grads = compress_mod.decompress(q, s)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        params, opt_state = optim.adamw_update(
            grads, opt_state, params, lr=lr_fn(step))
        return params, opt_state, err, loss

    with use_mesh(mesh):
        params = materialize(pdefs, jax.random.key(0))
        shardings = fix_divisibility(
            spec_tree(logical_axes(pdefs), mesh), abstract(pdefs))
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s else x, params, shardings,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
        opt_state = optim.adamw_init(params)
        err = (compress_mod.init_error(params) if a.compress_grads
               else jnp.zeros(()))
        step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

        start = 0
        if a.ckpt_dir and ckpt_mod.latest_step(a.ckpt_dir) is not None:
            tree = {"p": params, "o": opt_state}
            tree, start, _ = ckpt_mod.restore(a.ckpt_dir, tree)
            params, opt_state = tree["p"], tree["o"]
            print(f"resumed from step {start}")

        batches = synthetic.token_batches(a.batch, a.seq, cfg.vocab_size,
                                          start_idx=start * a.batch)
        for step in range(start, a.steps):
            t0 = time.monotonic()
            batch, loader_idx = next(batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, err, loss = step_fn(
                params, opt_state, err, batch, jnp.asarray(step))
            if step % 10 == 0 or step == a.steps - 1:
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"({time.monotonic()-t0:.2f}s/step)")
            if a.ckpt_dir and (step + 1) % a.ckpt_every == 0:
                ckpt_mod.save(a.ckpt_dir, step + 1,
                              {"p": params, "o": opt_state},
                              extra={"loader_idx": loader_idx})
    print("done")


if __name__ == "__main__":
    main()
