"""Production mesh construction + per-(arch x shape) input specs.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The mesh is built from the LIVE device set — elastic
restarts on a different pod count re-mesh here and re-shard from the
mesh-independent checkpoints (train/checkpoint.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.params import ParamDef, abstract, logical_axes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(devices=None, model_parallel: int = 16):
    """Elastic variant: mesh over whatever devices are alive."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = math.gcd(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"), devices=devices)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """Model inputs for one assigned shape, as ShapeDtypeStructs.

    train/prefill: token batch (+ labels for train, + modality stubs);
    decode: one new token + positions (the KV cache is separate state,
    see ``state_specs``).
    """
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    if kind == "train":
        d = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
             "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    elif kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    else:                                     # decode: one token per row
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32),
                "position": jax.ShapeDtypeStruct((batch,), i32)}
    if cfg.num_image_tokens:
        d["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        d["encoder_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_encoder_frames, cfg.d_model), jnp.bfloat16)
    return d


def input_axes(cfg: ModelConfig, shape_name: str) -> Dict:
    """Logical axes for every input (resolved against mesh rules)."""
    _, _, kind = SHAPES[shape_name]
    if kind == "decode":
        return {"tokens": ("batch", None), "position": ("batch",)}
    d = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if kind == "prefill":
        d.pop("labels")
    if cfg.num_image_tokens:
        d["image_embeds"] = ("batch", None, "embed")
    if cfg.encoder_layers:
        d["encoder_frames"] = ("batch", None, "embed")
    return d


def decode_state_specs(cfg: ModelConfig, shape_name: str):
    """(abstract cache, cache logical axes) for decode shapes."""
    seq, batch, kind = SHAPES[shape_name]
    assert kind == "decode"
    defs = lm.cache_defs(cfg, batch, seq)
    return abstract(defs), logical_axes(defs)


def shape_rules(cfg: ModelConfig, shape_name: str) -> Optional[Dict]:
    """Per-shape sharding-rule overrides.

    long_500k has global_batch=1: batch axes are useless, so the KV cache /
    SSD state shard their LONG axes over the data(+pod) axes instead.
    Decode with kv_heads not divisible by the 16-way model axis switches the
    cache to sequence-parallel (kv_seq over 'model') — the head partition is
    dropped by fix_divisibility.
    """
    if shape_name == "long_500k":
        return {"batch": None, "kv_seq": ("pod", "data"),
                "heads": ("model",), "seq": None}
    _, _, kind = SHAPES[shape_name]
    if kind == "decode" and cfg.num_kv_heads and cfg.num_kv_heads % 16 != 0:
        return {"kv_seq": "model"}
    return None


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic useful FLOPs per step: 6·N·D train, 2·N·D fwd-only
    (N = active params for MoE)."""
    seq, batch, kind = SHAPES[shape_name]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch                   # decode: one token per row
