"""Training loops: XR (paper workloads, BN-state threading) and LM.

Step functions are pure and jit-donated; the outer loop owns checkpointing
(atomic + async), resume-from-latest, loader-state capture, a preemption
hook, and a per-step heartbeat for straggler monitoring (DESIGN.md §7).
"""
from __future__ import annotations

import contextlib
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_mod
from repro.train import optim

f32 = jnp.float32


@dataclass
class TrainHooks:
    """Operational hooks for large-scale runs."""
    heartbeat: Optional[Callable[[int, float], None]] = None  # (step, dt)
    on_preempt: Optional[Callable[[int], None]] = None
    straggler_threshold: float = 3.0     # x median step time -> log warning
    log_every: int = 10


@dataclass
class TrainResult:
    params: Dict
    opt_state: object
    extras: Dict
    losses: list
    step: int


def make_xr_step(cfg, loss_fn, lr_fn, max_grad_norm: float = 1.0):
    """DetNet/EDSNet step: (params, bn_state, opt, batch, step) -> ..."""
    from repro.models import xr

    def step_fn(params, state, opt_state, batch, step):
        def loss_of(p):
            outs, new_state = xr.forward(cfg, p, state, batch["image"],
                                         train=True)
            loss, metrics = loss_fn(outs, batch)
            return loss, (new_state, metrics)

        (loss, (new_state, metrics)), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        grads, gnorm = optim.clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = optim.adamw_update(
            grads, opt_state, params, lr=lr_fn(step))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, new_state, opt_state, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def make_lm_step(cfg, lr_fn, max_grad_norm: float = 1.0):
    from repro.models import lm

    def step_fn(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lm.lm_loss, has_aux=True, argnums=1)(cfg, params, batch)
        grads, gnorm = optim.clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = optim.adamw_update(
            grads, opt_state, params, lr=lr_fn(step))
        return params, opt_state, dict(metrics, loss=loss, grad_norm=gnorm)

    return jax.jit(step_fn, donate_argnums=(0, 1))


def run_xr_training(cfg, params, state, batches: Iterator, *,
                    loss_fn, steps: int, lr: float = 1e-3,
                    ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                    hooks: Optional[TrainHooks] = None,
                    resume: bool = True) -> TrainResult:
    hooks = hooks if hooks is not None else TrainHooks()
    lr_fn = optim.cosine_schedule(lr, warmup=min(50, steps // 10 + 1),
                                  total=steps)
    step_fn = make_xr_step(cfg, loss_fn, lr_fn)
    opt_state = optim.adamw_init(params)
    start = 0

    if ckpt_dir and resume and ckpt_mod.latest_step(ckpt_dir) is not None:
        tree = {"params": params, "state": state, "opt": opt_state}
        tree, start, extra = ckpt_mod.restore(ckpt_dir, tree)
        params, state, opt_state = tree["params"], tree["state"], tree["opt"]
        batches = _skip_to(batches, extra.get("loader_idx", 0))

    preempted = []
    with contextlib.suppress(ValueError):      # non-main thread
        signal.signal(signal.SIGTERM, lambda *_: preempted.append(True))

    losses, times, writer = [], [], None
    for step in range(start, steps):
        t0 = time.monotonic()
        batch, loader_idx = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, opt_state, metrics = step_fn(
            params, state, opt_state, batch, jnp.asarray(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.monotonic() - t0
        times.append(dt)
        if hooks.heartbeat:
            hooks.heartbeat(step, dt)
        med = sorted(times)[len(times) // 2]
        if dt > hooks.straggler_threshold * med and len(times) > 10:
            print(f"[straggler] step {step} took {dt:.2f}s (median {med:.2f}s)")
        if hooks.log_every and step % hooks.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  + " ".join(f"{k}={float(v):.4f}" for k, v in metrics.items()
                             if k != "loss"))
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            writer = ckpt_mod.save_async(
                ckpt_dir, step + 1,
                {"params": params, "state": state, "opt": opt_state},
                extra={"loader_idx": loader_idx})
        if preempted:
            if hooks.on_preempt:
                hooks.on_preempt(step)
            if ckpt_dir:
                ckpt_mod.save(ckpt_dir, step + 1,
                              {"params": params, "state": state,
                               "opt": opt_state},
                              extra={"loader_idx": loader_idx})
            break
    if writer is not None:
        writer.join()
    return TrainResult(params, opt_state, {"state": state}, losses,
                       step + 1 if steps else 0)


def _skip_to(batches: Iterator, loader_idx: int) -> Iterator:
    """Loader state restore: synthetic loaders are pure in idx, so skipping
    is O(1) — they accept start_idx; for generic iterators we fast-forward."""
    return batches
