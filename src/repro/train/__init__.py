from repro.train import checkpoint, compress, loop, optim
