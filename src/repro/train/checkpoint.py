"""Fault-tolerant checkpointing: atomic, mesh-independent, resumable.

Layout:  <dir>/step_<N>/arrays.npz  + manifest.json
Commit protocol: write into ``step_<N>.tmp`` then ``os.replace`` — a crash
mid-write can never produce a half-checkpoint that restore would pick up.
Arrays are saved as host numpy per logical tensor (gathered if sharded), so
restore works on a DIFFERENT mesh/pod count: the launcher re-shards on load
(elastic restart, DESIGN.md §7).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "\x1f"          # flat-key separator (never appears in field names)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"k:{p.name}"
    return f"r:{p}"


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint for ``step``; prune to ``keep`` newest."""
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit
    _prune(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, extra=None, keep: int = 3
               ) -> threading.Thread:
    """Checkpoint on a writer thread: device_get happens eagerly (snapshot),
    serialization overlaps the next training steps."""
    flat = _flatten(tree)                       # snapshot before returning

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _prune(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``; optionally re-shard with
    a pytree of NamedShardings (elastic restart on a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    leaves = []
    for (path, like), sh in zip(paths, shard_leaves):
        key = _SEP.join(_key_str(p) for p in path)
        arr = data[key]
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step, manifest["extra"]
