"""Optimizers from scratch (no optax): AdamW, SGD-momentum, schedules.

State layout is a plain pytree (m, v, count) so checkpointing and sharding
treat it like any other tree; fp32 moments regardless of param dtype.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


class AdamWState(NamedTuple):
    m: any
    v: any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, f32)
    return AdamWState(jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params),
                      jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.01):
    c = state.count + 1
    bc1 = 1 - b1 ** c.astype(f32)
    bc2 = 1 - b2 ** c.astype(f32)

    def upd(g, m, v, p):
        gf = g.astype(f32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        step = step + weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(new_m, new_v, c)


class SGDState(NamedTuple):
    mom: any
    count: jax.Array


def sgd_init(params) -> SGDState:
    return SGDState(jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params),
                    jnp.zeros((), jnp.int32))


def sgd_update(grads, state: SGDState, params, *, lr, momentum=0.9):
    def upd(g, m, p):
        m2 = momentum * m + g.astype(f32)
        return (p.astype(f32) - lr * m2).astype(p.dtype), m2
    out = jax.tree.map(upd, grads, state.mom, params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, SGDState(new_m, state.count + 1)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(l.astype(f32))) for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(f32) * scale).astype(g.dtype),
                        grads), n


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        s = step.astype(f32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
