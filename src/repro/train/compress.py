"""INT8 gradient compression with error feedback (distributed-opt trick).

Before the data-parallel all-reduce, each leaf is quantized to int8 with a
per-leaf scale; the quantization residual is carried to the next step
(error feedback), which keeps SGD/Adam convergence unbiased in expectation.
Cuts all-reduce bytes 4x vs fp32 / 2x vs bf16 — applied inside train_step
so GSPMD reduces the int8 tensors (see launch/train.py --compress-grads).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def compress(grads, error):
    """-> (int8 codes, scales, new_error). Apply BEFORE the mean-reduce."""
    def one(g, e):
        gf = g.astype(f32) + e
        s = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(f32) * s
        return q, s, new_e
    out = jax.tree.map(one, grads, error)
    istuple = lambda x: isinstance(x, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=istuple)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=istuple)
    e = jax.tree.map(lambda t: t[2], out, is_leaf=istuple)
    return q, s, e


def decompress(q, s):
    return jax.tree.map(lambda qq, ss: qq.astype(f32) * ss, q, s)
